"""IVFZenIndex — clustered (inverted-file) retrieval over apex coordinates.

Filter-and-refine at production scale (paper §Perf; the supermetric-search
predecessor arXiv:1707.08370): instead of streaming every one of the N index
rows per query (``core.zen.knn_search``), partition the reduced (N, k)
coordinates with a k-means coarse quantizer and probe only the ``nprobe``
clusters whose centroids are closest to the query. Scan cost per query drops
from O(N) to O(nprobe * max_cluster_size); ``nprobe = n_clusters`` recovers
the flat result exactly.

Padded tile layout
------------------
Cluster sizes are data-dependent, so the inverted lists are packed into a
*static* shape: members are sorted by cluster and written into ``T`` fixed
``tile_rows``-row tiles per cluster,

  tile_coords : (C*T, tile_rows, k)   cluster c owns blocks c*T .. c*T+T-1
  tile_ids    : (C*T, tile_rows)      global row ids, -1 marks padding

with ``T`` sized by the largest cluster. Every probe therefore touches the
same block shapes under jit, the Pallas kernel can DMA tiles straight from a
scalar-prefetched probe list, and padding rows are masked (id == -1 -> +inf)
before the running top-k merge — never returned.

``search`` dispatches through ``kernels.ops.ivf_probe``: the fused Pallas
kernel on TPU, a fori_loop gather fallback elsewhere — both bounded-memory
(one tile per query per step). ``exact_rerank`` refines a candidate pool with
true distances in the original space (the PR-1 serving pattern).

Mutable corpus lifecycle
------------------------
The index is not frozen at build time. ``upsert`` assigns new points to their
nearest centroid and writes them into spare tile capacity (appending one
whole tile per cluster — *grow-by-tile* — when a list fills); ``delete``
tombstones rows by rewriting their id to the existing ``-1`` padding value,
so the probe kernels need no shape or code changes — a tombstone is
indistinguishable from padding and is masked the same way. Both are
control-plane host operations returning a *new* index (the search path stays
pure/jit); ``compact`` (optionally re-running ``index.kmeans``) repacks the
tiles when ``needs_compact`` reports that tombstones or tile over-allocation
crossed a threshold. ``save``/``load`` persist the live members in a
canonical, device-layout-free snapshot (``repro.checkpoint.index_io``) that
any later process — or a different shard count, via
``ShardedIVFZenIndex.load`` — can reload.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import index_io
from repro.core import metrics as metrics_lib
from repro.core import zen as zen_lib
from repro.kernels import ops as kernel_ops
from repro.kernels import pq as pq_lib
from repro.kernels import quantize as quant
from repro.kernels import scoring
from repro.kernels import tile_stage

from .kmeans import kmeans_assign, kmeans_fit

Array = jax.Array

#: snapshot kind tag for IVF indexes (flat and sharded share one canonical
#: on-disk representation: live members + global quantizer)
IVF_SNAPSHOT_KIND = "ivf-index"
#: tiered-store snapshot: the packed *tile layout* itself (not the member
#: list), so a memmapped load serves straight off the snapshot files
TILE_POOL_SNAPSHOT_KIND = "ivf-tile-pool"


def _check_ids(ids: np.ndarray) -> None:
    """Reject ids the int32 tile layout cannot represent.

    Ids are stored as int32 with ``-1`` reserved for padding/tombstones;
    a negative id would alias the dead-slot encoding and an id above
    int32 max would silently wrap negative in the ``astype`` — turning a
    live row into an unreturnable tombstone — so both are errors here.
    """
    if ids.size == 0:
        return
    if ids.min() < 0:
        raise ValueError("ids must be non-negative (-1 marks padding)")
    if ids.max() > np.iinfo(np.int32).max:
        raise ValueError(
            f"ids must fit int32 (max {np.iinfo(np.int32).max}), "
            f"got {ids.max()}")


def _dedupe_last_wins(
    ids: np.ndarray, rows: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Drop duplicate ids within an upsert batch, keeping the last
    occurrence of each (relative order otherwise preserved)."""
    _, first_of_rev = np.unique(ids[::-1], return_index=True)
    keep = np.sort(ids.size - 1 - first_of_rev)
    return ids[keep], rows[keep]


def snapshot_payload(index) -> Tuple[dict, dict]:
    """(arrays, meta) of an IVF index's canonical snapshot.

    The single definition of the on-disk payload — live members (gathered
    from either the flat or the sharded tile layout via ``_live_members``)
    plus the quantizer and geometry — shared by ``IVFZenIndex.save``,
    ``ShardedIVFZenIndex.save`` and ``launch.serve.ZenServer.save`` so the
    three save paths cannot drift.

    Quantised indexes persist their *raw* stored values (bf16/int8 member
    coords, uint8 PQ codes) plus their decode state — per-cluster scales
    for int8, the (M, 256, ds) codebooks for pq: load packs them back
    without a dequantise/requantise (or decode/re-encode) cycle, so a
    snapshot restores bit-identically onto any device count.
    """
    coords, ids, assign = index._live_members(raw=True)
    arrays = {
        "centroids": np.asarray(index.centroids, np.float32),
        "member_coords": coords,
        "member_ids": ids.astype(np.int32),
        "member_assign": assign.astype(np.int32),
    }
    if index.tile_scales is not None:
        arrays["cluster_scales"] = np.asarray(index.tile_scales, np.float32)
    if getattr(index, "codebooks", None) is not None:
        arrays["pq_codebooks"] = np.asarray(index.codebooks, np.float32)
    meta = {"n_clusters": index.n_clusters, "tile_rows": index.tile_rows,
            "storage": index.storage,
            # churn counter: a restored index must key cache entries on the
            # *published* generation, not restart from 0 (replication keys
            # replica caches on this — repro.launch.replicate). Sharded
            # mesh indexes are immutable and carry no counter; the wrapper
            # ZenIndex generation (ZenServer.save overwrites this key) is
            # authoritative for them.
            "generation": int(getattr(index, "generation", 0))}
    return arrays, meta


def _packed_scales(packed: np.ndarray) -> np.ndarray:
    """(C, 1) per-cluster int8 scales from a packed f32 (C, rows, k) layout.

    Equals ``quant.cluster_scales`` over the members (padding rows are zero
    and cannot carry the absmax); stale tombstone coords left behind by
    churn can only keep a scale larger than the live rows need — never
    wrong, at worst a little conservative until the next compact.
    """
    return quant.symmetric_scales(
        np.abs(np.asarray(packed, np.float32)).max(axis=(1, 2)))[:, None]


def _encode_packed(
    packed: np.ndarray, storage: str
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Encode a packed f32 (C, rows, k) layout into its storage dtype.

    Returns ``(values, (C, 1) per-cluster scales or None)``.
    """
    quant.check_storage(storage)
    packed = np.asarray(packed, np.float32)
    if storage == "float32":
        return packed, None
    if storage == "bfloat16":
        return packed.astype(quant.np_dtype("bfloat16")), None
    scales = _packed_scales(packed)
    return quant.quantize(packed, scales[:, :, None]), scales


def _coerce_member_storage(
    coords: np.ndarray,
    assign: np.ndarray,
    n_clusters: int,
    storage: str,
    scales: Optional[np.ndarray],
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Member coords as restored-or-fresh -> (storage-dtype values, scales).

    Shared by the single-host (:meth:`IVFZenIndex.from_members`) and sharded
    (:meth:`ShardedIVFZenIndex._from_members`) restore paths so the
    bit-identity contract cannot drift between them: already-quantised int8
    values pass through with their persisted per-cluster ``scales`` (no
    dequantise/requantise cycle); f32 input under a narrow ``storage`` is
    encoded here, with scales derived from the *global* assignment before
    any shard split or tile packing.
    """
    quant.check_storage(storage)
    if storage == "pq":
        raise NotImplementedError(
            "storage='pq' packs uint8 code tiles with their codebooks and "
            "is only supported by the single-host IVFZenIndex "
            "(IVFZenIndex.from_members); sharded/tiered layouts take "
            + "/".join(quant.SCALAR_STORAGE_DTYPES))
    coords = np.asarray(coords)
    if coords.dtype == np.int8:
        if scales is None:
            raise ValueError("int8 member coords need per-cluster scales")
        return coords, np.asarray(scales, np.float32)
    if storage == "int8":
        scales = quant.cluster_scales(coords, assign, n_clusters)
        return quant.quantize(coords, scales[assign]), scales
    return coords.astype(quant.np_dtype(storage)), None


def _pack_tiles(
    coords: np.ndarray,
    assign: np.ndarray,
    ids: np.ndarray,
    n_clusters: int,
    tile_rows: int,
    *,
    min_tiles: int = 1,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pack member rows into the padded inverted-list tile layout (host-side).

    Args:
      coords:  (n, k) member apex coordinates, in any storage dtype
               (f32 / bf16 / int8 values are packed as-is — quantisation is
               the caller's concern).
      assign:  (n,) cluster id per member.
      ids:     (n,) global row ids to store (any non-negative int32 values).
      n_clusters: number of clusters C.
      tile_rows:  rows per tile.
      min_tiles:  lower bound on tiles per cluster T (used to align shard /
                  growth layouts).

    Returns ``(packed (C, T*tile_rows, k) in ``coords.dtype``, out_ids
    (C, T*tile_rows) int32 with -1 padding, T)``.
    """
    coords = np.asarray(coords)
    n, kdim = coords.shape
    counts = np.bincount(assign, minlength=n_clusters) if n else np.zeros(
        n_clusters, np.int64)
    cmax = int(counts.max()) if n else 0
    per_cluster = max(
        min_tiles * tile_rows,
        int(math.ceil(cmax / tile_rows)) * tile_rows if cmax else 0,
    )
    T = per_cluster // tile_rows
    out_ids = np.full((n_clusters, per_cluster), -1, np.int64)
    packed = np.zeros((n_clusters, per_cluster, kdim), coords.dtype)
    if n:
        order = np.argsort(assign, kind="stable")
        starts = np.cumsum(counts) - counts
        pos = np.arange(n) - np.repeat(starts, counts)
        out_ids[assign[order], pos] = ids[order]
        packed[assign[order], pos] = coords[order]
    return packed, out_ids.astype(np.int32), T


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IVFZenIndex:
    """Clustered Zen index: k-means centroids + padded inverted-list tiles.

    Attributes:
      centroids:   (C, k) f32 coarse-quantizer centroids (always full
                   precision: the coarse ranking is O(Q*C), not the hot loop).
      tile_coords: (C*T, tile_rows, k) packed member apex coordinates, in
                   the ``storage`` dtype; cluster ``c`` owns blocks
                   ``c*T .. c*T+T-1``.
      tile_ids:    (C*T, tile_rows) int32 global row ids; ``-1`` marks both
                   never-used padding and tombstoned (deleted) rows — the
                   probe kernels mask the two identically.
      n_clusters:  C.
      tiles_per_cluster: T (grows when ``upsert`` fills a list).
      tile_rows:   rows per tile (keep a multiple of 128 for the TPU kernel).
      n_valid:     number of live (searchable) rows.
      n_deleted:   tombstones accumulated since the last build/compact —
                   drives the ``needs_compact`` trigger.
      storage:     resident dtype of ``tile_coords``, one of
                   ``kernels.quantize.STORAGE_DTYPES``. Estimator
                   accumulation is f32 regardless; the probe kernels
                   dequantise (or LUT-gather, for "pq") in register. Under
                   "pq" the ``tile_coords`` array holds (C*T, tile_rows, M)
                   uint8 *codes* instead of k-wide coordinates.
      tile_scales: (C, 1) f32 per-cluster symmetric int8 scales, or ``None``
                   for f32/bf16/pq storage. Per *cluster* — not per tile —
                   so the quantised values depend only on the global
                   assignment, never on tile packing or shard count.
      codebooks:   (M, 256, ds) f32 PQ subspace codebooks (``kernels.pq``)
                   when ``storage == "pq"``, else ``None``. Codes are
                   residuals against the member's *globally assigned*
                   centroid — the same layout-independence invariant as the
                   int8 scales.
      generation:  monotonic churn counter — bumped by every
                   upsert/delete/compact that changes the searchable state.
                   The serving frontend's result cache keys on it
                   (``repro.serving.cache``), so cached responses can never
                   outlive the index state that produced them.
    """

    centroids: Array    # (C, k) f32 coarse-quantizer centroids
    tile_coords: Array  # (C*T, tile_rows, k) packed member coordinates
    tile_ids: Array     # (C*T, tile_rows) int32 global row ids, -1 = padding
    n_clusters: int
    tiles_per_cluster: int
    tile_rows: int
    n_valid: int        # number of live (un-padded, un-deleted) index rows
    n_deleted: int = 0  # tombstones since the last build/compact
    storage: str = "float32"        # resident dtype of tile_coords
    tile_scales: Optional[Array] = None  # (C, 1) int8 dequant scales
    codebooks: Optional[Array] = None    # (M, 256, ds) PQ codebooks
    generation: int = 0  # churn counter; invalidates frontend cache entries

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        # generation rides as a *child* (traced leaf), never in the static
        # aux: it is host-only cache metadata, and making it jit-static
        # would force a full `_ivf_search` recompile — and a permanently
        # retained cache entry — on every churn event
        children = (self.centroids, self.tile_coords, self.tile_ids,
                    self.tile_scales, self.codebooks, self.generation)
        aux = (self.n_clusters, self.tiles_per_cluster, self.tile_rows,
               self.n_valid, self.n_deleted, self.storage)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        (centroids, tile_coords, tile_ids, tile_scales, codebooks,
         generation) = children
        return cls(centroids, tile_coords, tile_ids, *aux[:5],
                   storage=aux[5], tile_scales=tile_scales,
                   codebooks=codebooks, generation=generation)

    @property
    def size(self) -> int:
        return self.n_valid

    @property
    def dim(self) -> int:
        return int(self.centroids.shape[1])

    # -- build ---------------------------------------------------------------
    @classmethod
    def build(
        cls,
        coords: Array,
        n_clusters: int,
        *,
        ids: Optional[Sequence[int]] = None,
        tile_rows: int = 128,
        n_iters: int = 15,
        chunk: int = 16384,
        key: Optional[Array] = None,
        storage: str = "float32",
        pq_m: Optional[int] = None,
    ) -> "IVFZenIndex":
        """Cluster (N, k) apex coordinates and pack the inverted lists.

        Args:
          coords:     (N, k) apex coordinates to index.
          n_clusters: requested cluster count (clamped to [1, N]).
          ids:        optional (N,) non-negative int32 global ids to store
                      with each row; defaults to ``arange(N)``. Explicit ids
                      are what make churn (``upsert``/``delete``/``compact``)
                      and checkpoint reload id-stable.
          tile_rows:  rows per packed tile; keep a multiple of 128 so tiles
                      are lane-aligned for the TPU probe kernel.
          n_iters:    Lloyd iterations for the quantizer fit.
          chunk:      row chunk of the k-means assignment passes.
          key:        PRNG key for the k-means++ seeding.
          storage:    resident dtype of the packed tiles — one of
                      ``kernels.quantize.STORAGE_DTYPES``: "bfloat16" is a
                      plain cast, "int8" per-cluster symmetric scales, and
                      "pq" per-cluster-residual product quantisation
                      (``kernels.pq``: each member stores ``pq_m`` uint8
                      codes, codebooks trained here with a fold of ``key``).
                      The quantizer fit always runs on the f32 coordinates.
          pq_m:       PQ subspace count M (storage="pq" only); default
                      ``kernels.pq.default_m(k)`` = ~4 dims per code byte.

        Returns a fresh index with ``n_valid == N`` and no tombstones. The
        quantizer fit and assignment run jit-compiled and chunked
        (``index.kmeans``); the pack itself is a one-off host-side sort.
        """
        quant.check_storage(storage)
        key = key if key is not None else jax.random.PRNGKey(0)
        n, kdim = coords.shape
        n_clusters = max(1, min(n_clusters, n))
        centroids, _ = kmeans_fit(
            coords, n_clusters, key=key, n_iters=n_iters, chunk=chunk
        )
        assign = np.asarray(kmeans_assign(coords, centroids, chunk=chunk))
        ids_np = (np.arange(n, dtype=np.int64) if ids is None
                  else np.asarray(ids, np.int64).reshape(n))
        _check_ids(ids_np)
        coords_np = np.asarray(coords, np.float32)
        codebooks = None
        if storage == "pq":
            residuals = coords_np - np.asarray(centroids, np.float32)[assign]
            codebooks = pq_lib.train_codebooks(
                residuals, pq_m or pq_lib.default_m(kdim),
                key=jax.random.fold_in(key, 11), n_iters=n_iters)
            values = pq_lib.encode(residuals, codebooks)
            scales = None
        else:
            values, scales = None, None
        packed_src = values if values is not None else coords_np
        packed, out_ids, T = _pack_tiles(
            packed_src, assign, ids_np, n_clusters, tile_rows)
        if storage != "pq":
            packed, scales = _encode_packed(packed, storage)
        width = packed.shape[-1]
        return cls(
            centroids=centroids,
            tile_coords=jnp.asarray(
                packed.reshape(n_clusters * T, tile_rows, width)),
            tile_ids=jnp.asarray(
                out_ids.reshape(n_clusters * T, tile_rows)),
            n_clusters=n_clusters,
            tiles_per_cluster=T,
            tile_rows=tile_rows,
            n_valid=n,
            storage=storage,
            tile_scales=None if scales is None else jnp.asarray(scales),
            codebooks=None if codebooks is None else jnp.asarray(codebooks),
        )

    # -- mutation (control plane: host-side, returns a new index) -----------
    def delete(self, ids: Sequence[int]) -> "IVFZenIndex":
        """Tombstone the given global ids; unknown ids are ignored.

        The rows' id slots are rewritten to ``-1`` — exactly the padding
        value the probe kernels already mask to ``+inf`` — so search needs no
        shape or code change and never returns a deleted row. The stale
        coordinates stay in ``tile_coords`` until ``compact`` repacks them
        away. O(C*T*tile_rows) host work; the device arrays are re-uploaded.

        Returns a new index with ``n_valid`` decreased by the number of rows
        actually removed (``self`` unchanged).
        """
        ids_np = np.unique(np.asarray(ids, np.int64).ravel())
        tids = np.asarray(self.tile_ids)
        mask = (tids >= 0) & np.isin(tids, ids_np)
        removed = int(mask.sum())
        if removed == 0:
            return self
        tids = tids.copy()
        tids[mask] = -1
        return dataclasses.replace(
            self,
            tile_ids=jnp.asarray(tids),
            n_valid=self.n_valid - removed,
            n_deleted=self.n_deleted + removed,
            generation=self.generation + 1,
        )

    def upsert(self, ids: Sequence[int], coords: Array) -> "IVFZenIndex":
        """Insert (or replace) rows keyed by global id.

        Args:
          ids:    (B,) non-negative global ids. An id already in the index is
                  *replaced*: its old row is tombstoned first (it may move to
                  a different cluster). Duplicate ids within the batch keep
                  the last occurrence.
          coords: (B, k) apex coordinates (e.g. ``transform.transform(X)``).

        Each new row is assigned to its nearest centroid
        (``kmeans_assign`` with the *existing* quantizer — the paper's point
        that a fitted transform keeps projecting new objects) and written
        into a free slot of that cluster's tiles, reusing tombstoned slots
        first. When a cluster's list is full the layout *grows by one or
        more whole tiles for every cluster* (T -> T') so all shapes stay
        uniform and the probe kernels recompile once, not per cluster.

        Returns a new index (``self`` unchanged).
        """
        ids_np = np.asarray(ids, np.int64).ravel()
        _check_ids(ids_np)
        coords_np = np.asarray(coords, np.float32).reshape(
            ids_np.size, self.dim)
        if ids_np.size == 0:
            return self
        ids_np, coords_np = _dedupe_last_wins(ids_np, coords_np)

        base = self.delete(ids_np)  # replaced rows become tombstones
        C, T, rows = self.n_clusters, base.tiles_per_cluster, self.tile_rows
        # stored width: k for scalar storage, M code bytes under "pq"
        width = int(base.tile_coords.shape[-1])
        tids = np.asarray(base.tile_ids).reshape(C, T * rows).copy()
        # mutate the *stored* bytes in place and touch only the clusters
        # the batch lands in: untouched clusters keep their exact tiles and
        # scales, and the host work stays O(batch clusters), not O(N)
        tvals = np.asarray(base.tile_coords).reshape(C, T * rows, width).copy()
        scl = (None if base.tile_scales is None
               else np.asarray(base.tile_scales, np.float32).copy())

        assign = np.asarray(
            kmeans_assign(jnp.asarray(coords_np), self.centroids))
        counts = np.bincount(assign, minlength=C)
        deficit = counts - (tids < 0).sum(axis=1)
        if deficit.max() > 0:  # grow-by-tile: append whole empty tiles
            grow = int(math.ceil(deficit.max() / rows))
            tids = np.concatenate(
                [tids, np.full((C, grow * rows), -1, np.int32)], axis=1)
            tvals = np.concatenate(
                [tvals, np.zeros((C, grow * rows, width), tvals.dtype)],
                axis=1)
            T += grow
        cbs = (None if self.codebooks is None
               else np.asarray(self.codebooks, np.float32))
        cents = np.asarray(self.centroids, np.float32)
        for c in np.unique(assign):
            sel = np.flatnonzero(assign == c)
            slots = np.flatnonzero(tids[c] < 0)[: sel.size]
            tids[c, slots] = ids_np[sel]
            if cbs is not None:
                # pq: residual-encode against this cluster's centroid with
                # the *frozen* codebooks — same invariant as upserting into
                # the frozen coarse quantizer; drift is reclaimed by
                # compact(recluster=True), which retrains both
                tvals[c, slots] = pq_lib.encode(
                    coords_np[sel] - cents[c], cbs)
            elif scl is None:  # f32 / bf16: a plain (casting) write
                tvals[c, slots] = coords_np[sel]
            else:
                # int8: dequantise this cluster's block, write the rows,
                # re-derive its scale from the full block content (same
                # absmax rule as _encode_packed) and requantise — the
                # absmax pinning makes this lossless when the scale holds
                blk = quant.dequantize(tvals[c], scl[c, 0])
                blk[slots] = coords_np[sel]
                scl[c, 0] = quant.symmetric_scales(np.abs(blk).max())
                tvals[c] = quant.quantize(blk, scl[c, 0])
        # every insert lands in a previously-dead slot, so the batch
        # reclaims up to `inserted` tombstones — without the credit, a pure
        # in-place refresh (replace existing ids) would inflate n_deleted
        # and trip needs_compact with nothing reclaimable
        return dataclasses.replace(
            base,
            tile_coords=jnp.asarray(tvals.reshape(C * T, rows, width)),
            tile_ids=jnp.asarray(tids.reshape(C * T, rows).astype(np.int32)),
            tiles_per_cluster=T,
            n_valid=base.n_valid + ids_np.size,
            n_deleted=max(0, base.n_deleted - int(ids_np.size)),
            tile_scales=None if scl is None else jnp.asarray(scl),
            generation=self.generation + 1,
        )

    @property
    def tombstone_ratio(self) -> float:
        """Fraction of once-live rows that are now tombstones."""
        return self.n_deleted / max(self.n_valid + self.n_deleted, 1)

    def cluster_sizes(self) -> np.ndarray:
        """(C,) live member count per cluster (host-side)."""
        tids = np.asarray(self.tile_ids).reshape(
            self.n_clusters, self.tiles_per_cluster * self.tile_rows)
        return (tids >= 0).sum(axis=1)

    @property
    def imbalance(self) -> float:
        """Max/mean live cluster load; 1.0 is perfectly balanced.

        Upserts assign into the *frozen* quantizer, so a drifting corpus
        concentrates into few cells; every grow-by-tile then inflates T for
        all clusters and the probe scans T tiles per probed cluster. High
        imbalance is the signal that ``compact(recluster=True)`` — not a
        mere repack — is needed.
        """
        sizes = self.cluster_sizes()
        mean = float(sizes.mean())
        return float(sizes.max()) / mean if mean > 0 else 0.0

    def needs_compact(
        self,
        *,
        max_tombstone_ratio: float = 0.2,
        max_tile_slack: float = 2.0,
        max_imbalance: Optional[float] = None,
    ) -> bool:
        """True when churn has degraded the packed layout enough to rebuild.

        Triggers when (a) more than ``max_tombstone_ratio`` of the
        once-live rows are tombstones (probes scan dead slots), (b) the
        allocated tiles-per-cluster exceeds ``max_tile_slack`` times what
        the current largest list actually needs (grow-by-tile inflated every
        cluster; a repack would shrink T and the probe cost with it), or
        (c) ``max_imbalance`` is given and :attr:`imbalance` exceeds it —
        that one calls for ``compact(recluster=True)``. It is off by
        default because a healthy k-means fit on clustered data is already
        skewed; pick a threshold relative to the freshly built index.
        """
        if self.tombstone_ratio > max_tombstone_ratio:
            return True
        if max_imbalance is not None and self.imbalance > max_imbalance:
            return True
        t_needed = max(
            1, -(-int(self.cluster_sizes().max()) // self.tile_rows))
        return self.tiles_per_cluster >= max_tile_slack * t_needed

    def compact(
        self,
        *,
        recluster: bool = False,
        n_clusters: Optional[int] = None,
        n_iters: int = 15,
        chunk: int = 16384,
        key: Optional[Array] = None,
    ) -> "IVFZenIndex":
        """Repack the live rows into a minimal tile layout.

        Without ``recluster`` the existing quantizer and assignments are
        kept — a pure repack that drops tombstones and shrinks
        grow-by-tile slack. With ``recluster=True`` (or an explicit
        ``n_clusters``) the quantizer is refit on the live coordinates with
        ``index.kmeans`` first — the full re-balance for heavily churned or
        drifted corpora. Ids are preserved either way.

        A compaction with nothing to reclaim — no tombstones, already at
        the minimal tiles-per-cluster, no refit requested — returns
        ``self`` unchanged, so a periodic ``compact()`` on a healthy index
        never bumps ``generation`` (which would needlessly invalidate the
        serving frontend's result cache).
        """
        if not recluster and n_clusters is None and self.n_deleted == 0:
            t_needed = max(
                1, -(-int(self.cluster_sizes().max()) // self.tile_rows))
            if self.tiles_per_cluster == t_needed:
                return self
        pq = self.storage == "pq"
        refit = recluster or n_clusters is not None
        # a pure pq repack moves the *raw* uint8 codes (a decode/re-encode
        # cycle could flip codes that tie between duplicated codebook
        # entries); only a refit decodes, because residual anchors move
        coords, ids, assign = self._live_members(raw=pq and not refit)
        if refit:
            key = key if key is not None else jax.random.PRNGKey(0)
            n_clusters = n_clusters or self.n_clusters
            n_clusters = max(1, min(n_clusters, max(len(ids), 1)))
            if len(ids) == 0:
                centroids = np.asarray(self.centroids, np.float32)[:n_clusters]
            else:
                centroids, _ = kmeans_fit(
                    jnp.asarray(coords), n_clusters, key=key,
                    n_iters=n_iters, chunk=chunk)
                assign = np.asarray(kmeans_assign(
                    jnp.asarray(coords), centroids, chunk=chunk))
            centroids = jnp.asarray(centroids)
        else:
            n_clusters = self.n_clusters
            centroids = self.centroids
        codebooks = None
        if pq:
            books = np.asarray(self.codebooks, np.float32)
            if refit:
                if len(ids):
                    residuals = (np.asarray(coords, np.float32)
                                 - np.asarray(centroids, np.float32)[assign])
                    books = pq_lib.train_codebooks(
                        residuals, books.shape[0],
                        key=jax.random.fold_in(key, 11), n_iters=n_iters)
                    coords = pq_lib.encode(residuals, books)
                else:  # emptied index: keep the old books, pack no codes
                    coords = np.zeros((0, books.shape[0]), np.uint8)
            codebooks = jnp.asarray(books)
            values, out_ids, T = _pack_tiles(
                coords, assign, ids, n_clusters, self.tile_rows)
            scales = None
        else:
            packed, out_ids, T = _pack_tiles(
                coords, assign, ids, n_clusters, self.tile_rows)
            values, scales = _encode_packed(packed, self.storage)
        width = values.shape[-1]
        return IVFZenIndex(
            centroids=centroids,
            tile_coords=jnp.asarray(values.reshape(
                n_clusters * T, self.tile_rows, width)),
            tile_ids=jnp.asarray(out_ids.reshape(
                n_clusters * T, self.tile_rows)),
            n_clusters=n_clusters,
            tiles_per_cluster=T,
            tile_rows=self.tile_rows,
            n_valid=len(ids),
            storage=self.storage,
            tile_scales=None if scales is None else jnp.asarray(scales),
            codebooks=codebooks,
            generation=self.generation + 1,
        )

    def _host_tiles_f32(self) -> np.ndarray:
        """(C*T, rows, k) dequantised/decoded f32 host copy of the tiles.

        Dead slots (padding/tombstones) come back as whatever their stored
        bytes decode to — callers filter by ``tile_ids >= 0`` before use.
        """
        vals = np.asarray(self.tile_coords)
        if self.codebooks is not None:
            books = np.asarray(self.codebooks, np.float32)
            ct = vals.shape[0]
            flat = pq_lib.decode(
                vals.reshape(ct * self.tile_rows, -1), books, self.dim)
            out = flat.reshape(ct, self.tile_rows, self.dim)
            cents = np.asarray(self.centroids, np.float32)
            return out + np.repeat(
                cents, self.tiles_per_cluster, axis=0)[:, None, :]
        if self.tile_scales is not None:
            per_block = np.repeat(  # cluster scale of every tile block
                np.asarray(self.tile_scales, np.float32)[:, 0],
                self.tiles_per_cluster)
            return quant.dequantize(vals, per_block[:, None, None])
        return np.asarray(vals, np.float32)

    def _live_members(
        self, *, raw: bool = False
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Host copies of the live rows: (coords (n, k), ids (n,),
        assign (n,)), ordered by cluster then slot. ``raw`` keeps the
        coords in the storage dtype (snapshot path); the default
        dequantises to f32 (compact / recluster path)."""
        tids = np.asarray(self.tile_ids)          # (C*T, rows)
        valid = tids >= 0
        block_cluster = np.arange(tids.shape[0]) // self.tiles_per_cluster
        assign = np.broadcast_to(
            block_cluster[:, None], tids.shape)[valid]
        tiles = (np.asarray(self.tile_coords) if raw
                 else self._host_tiles_f32())
        coords = tiles[valid]
        return (coords, tids[valid].astype(np.int64),
                assign.astype(np.int64))

    @classmethod
    def from_members(
        cls,
        coords: np.ndarray,
        ids: np.ndarray,
        assign: np.ndarray,
        centroids: Array,
        n_clusters: int,
        tile_rows: int,
        *,
        storage: str = "float32",
        scales: Optional[np.ndarray] = None,
        codebooks: Optional[np.ndarray] = None,
        pq_m: Optional[int] = None,
        generation: int = 0,
    ) -> "IVFZenIndex":
        """Pack canonical host member arrays into a fresh index.

        The checkpoint-restore path (also used by ``launch.serve``): given
        the live members ``(coords (n, k), ids (n,), assign (n,))`` and an
        already-fitted quantizer, rebuild the padded tile layout with no
        tombstones and minimal tiles-per-cluster.

        ``coords`` may arrive already in the storage dtype (a quantised
        snapshot, with its persisted per-cluster ``scales`` — or, under
        ``storage="pq"``, uint8 codes with their persisted ``codebooks``) —
        the values are packed as-is, no dequantise/requantise (or
        decode/re-encode) cycle, which is what makes reloads bit-identical.
        f32 ``coords`` with a narrow ``storage`` are encoded here instead
        (fresh scales; for "pq", fresh codebooks unless given — ``pq_m``
        sets their subspace count).
        """
        assign64 = np.asarray(assign, np.int64)
        if storage == "pq":
            quant.check_storage(storage)
            coords = np.asarray(coords)
            if coords.dtype == np.uint8:  # restored codes: pack as-is
                if codebooks is None:
                    raise ValueError(
                        "uint8 PQ member codes need their codebooks")
                values = coords
            else:
                residuals = (np.asarray(coords, np.float32)
                             - np.asarray(centroids, np.float32)[assign64])
                if codebooks is None:
                    codebooks = pq_lib.train_codebooks(
                        residuals, pq_m or pq_lib.default_m(coords.shape[1]))
                values = pq_lib.encode(
                    residuals, np.asarray(codebooks, np.float32))
            scales = None
        else:
            values, scales = _coerce_member_storage(
                coords, assign64, n_clusters, storage, scales)
            codebooks = None
        packed, out_ids, T = _pack_tiles(
            values, assign64, np.asarray(ids, np.int64),
            n_clusters, tile_rows)
        width = values.shape[1]
        return cls(
            centroids=jnp.asarray(centroids),
            tile_coords=jnp.asarray(
                packed.reshape(n_clusters * T, tile_rows, width)),
            tile_ids=jnp.asarray(out_ids.reshape(n_clusters * T, tile_rows)),
            n_clusters=n_clusters,
            tiles_per_cluster=T,
            tile_rows=tile_rows,
            n_valid=values.shape[0],
            storage=storage,
            tile_scales=None if scales is None else jnp.asarray(scales),
            codebooks=None if codebooks is None else jnp.asarray(codebooks),
            generation=generation,
        )

    # -- persistence ---------------------------------------------------------
    def save(self, directory: str) -> str:
        """Persist the index as a versioned snapshot (atomic publish).

        Only the *live* members are written (tombstones and grow-by-tile
        slack are dropped — a save is implicitly a repack), together with the
        quantizer, as canonical host arrays with no device layout. The same
        snapshot loads as a single-host index (:meth:`load`) or resharded
        onto any device count (``ShardedIVFZenIndex.load``).
        """
        return index_io.save_state(
            directory, *snapshot_payload(self), kind=IVF_SNAPSHOT_KIND)

    @classmethod
    def load(
        cls, directory: str, *, tile_rows: Optional[int] = None
    ) -> "IVFZenIndex":
        """Load a snapshot written by :meth:`save` (either variant).

        Args:
          directory: snapshot directory.
          tile_rows: override the stored tile geometry (e.g. retune for a
                     different accelerator); defaults to the saved value.

        Raises ``checkpoint.CheckpointFormatError`` on a version/kind
        mismatch.
        """
        arrays, meta = index_io.load_state(
            directory, expect_kind=IVF_SNAPSHOT_KIND)
        return cls.from_members(
            arrays["member_coords"],
            arrays["member_ids"],
            arrays["member_assign"],
            jnp.asarray(arrays["centroids"]),
            int(meta["n_clusters"]),
            tile_rows or int(meta["tile_rows"]),
            storage=meta.get("storage", "float32"),
            scales=arrays.get("cluster_scales"),
            codebooks=arrays.get("pq_codebooks"),
            generation=int(meta.get("generation", 0)),
        )

    # -- search --------------------------------------------------------------
    def search(
        self,
        queries: Array,
        n_neighbors: int = 10,
        nprobe: int = 8,
        mode: str = "zen",
        *,
        force_kernel: bool = False,
    ) -> Tuple[Array, Array]:
        """Probe the ``nprobe`` nearest clusters per query, return best-k.

        Returns (distances, indices), each (Q, n_neighbors), ascending; ids
        refer to rows of the original coordinate matrix (valid ids only —
        slots the probed clusters cannot fill come back as (+inf, -1)).
        ``nprobe = n_clusters`` scans every list and matches the flat
        ``knn_search`` result exactly. On a fully-emptied index the full
        (Q, n_neighbors) shape is kept, every slot (+inf, -1).
        """
        assert n_neighbors > 0, n_neighbors
        if self.n_valid == 0:
            return _empty_result(queries.shape[0], n_neighbors)
        n_neighbors = min(n_neighbors, self.n_valid)
        nprobe = max(1, min(nprobe, self.n_clusters))
        return _ivf_search(
            self, queries, n_neighbors=n_neighbors, nprobe=nprobe, mode=mode,
            force_kernel=force_kernel,
        )

    def probe_clusters(
        self, queries: Array, nprobe: int, mode: str = "zen"
    ) -> Array:
        """(Q, nprobe) ids of the clusters nearest each query's coordinates."""
        nprobe = max(1, min(nprobe, self.n_clusters))
        return _probe_clusters(queries, self.centroids, nprobe, mode)


def _empty_result(n_queries: int, n_neighbors: int) -> Tuple[Array, Array]:
    """The all-unfilled search result: (Q, n_neighbors) of (+inf, -1)."""
    return (jnp.full((n_queries, n_neighbors), jnp.inf, jnp.float32),
            jnp.full((n_queries, n_neighbors), -1, jnp.int32))


def _probe_clusters(
    queries: Array, centroids: Array, nprobe: int, mode: str
) -> Array:
    """Coarse ranking: the ``nprobe`` estimator-nearest centroids per query.

    One small (Q, C) matrix — the sublinear part of the search is never
    materialising anything N-sized after this. The single shared
    implementation keeps single-host, sharded and diagnostic probes
    identical (same tie-breaking, same estimator mode).
    """
    cd = zen_lib.estimate_pdist(queries, centroids, mode)
    _, probes = jax.lax.top_k(-cd, nprobe)
    return probes.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n_neighbors", "nprobe", "mode", "force_kernel"),
)
def _ivf_search(
    index: IVFZenIndex,
    queries: Array,
    *,
    n_neighbors: int,
    nprobe: int,
    mode: str,
    force_kernel: bool,
) -> Tuple[Array, Array]:
    probes = _probe_clusters(queries, index.centroids, nprobe, mode)
    if index.codebooks is not None:
        # pq: fold the estimator mode into per-(query, cluster) ADC tables
        # once, then stream the uint8 code tiles through the LUT-gather
        # probe — it needs no mode argument and never sees a coordinate
        luts = pq_lib.build_luts(
            queries, index.centroids, index.codebooks, probes,
            scoring.MODE_IDS[mode])
        return kernel_ops.ivf_probe_pq(
            index.tile_coords, index.tile_ids, probes, luts, n_neighbors,
            tiles_per_cluster=index.tiles_per_cluster,
            force_kernel=force_kernel,
        )
    return kernel_ops.ivf_probe(
        queries, index.tile_coords, index.tile_ids, probes, n_neighbors,
        mode, tiles_per_cluster=index.tiles_per_cluster,
        tile_scales=index.tile_scales, force_kernel=force_kernel,
    )


def exact_rerank(
    queries: Array,
    corpus: Array,
    cand_ids: Array,
    n_neighbors: int,
    *,
    metric: str = "euclidean",
) -> Tuple[Array, Array]:
    """Refine a (Q, C) candidate pool with true distances (serving pattern).

    Gathers the candidates' original vectors, scores them exactly under
    ``metric`` — the registry's pairwise function, evaluated per query over
    its own candidate pool, so non-Euclidean metrics (jsd, qform, ...)
    re-rank with their true distance, not a Euclidean surrogate — and
    returns the best ``n_neighbors``. Padding candidates (id == -1) are
    masked out, never returned (unless the pool holds fewer than
    ``n_neighbors`` valid candidates).
    """
    m = metrics_lib.get_metric(metric)
    safe_ids = jnp.maximum(cand_ids, 0)
    cands = corpus[safe_ids]                         # (Q, C, m)
    qn = m.normalize(queries) if m.normalize is not None else queries
    cn = m.normalize(cands) if m.normalize is not None else cands
    d = jax.vmap(lambda qr, cr: m.pdist(qr[None, :], cr)[0])(
        qn.astype(jnp.float32), cn.astype(jnp.float32))  # (Q, C)
    d = jnp.where(cand_ids >= 0, d, jnp.inf)
    n_neighbors = min(n_neighbors, cand_ids.shape[1])
    dd, pos = jax.lax.top_k(-d, n_neighbors)
    return -dd, jnp.take_along_axis(cand_ids, pos, axis=1)


def _pack_sharded_tiles(
    coords: np.ndarray,
    assign: np.ndarray,
    ids: np.ndarray,
    n_clusters: int,
    n_shards: int,
    tile_rows: int,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pack members into per-shard inverted lists with a common T.

    Members are dealt round-robin across shards *within each cluster* (a
    stable cluster-then-position sort, strided by shard) so every shard
    holds ~1/S of every inverted list: per-shard max list size — and with
    it T, hence tile memory S*C*T — stays ~1/S of the global max no matter
    how the caller ordered the rows. (A contiguous split would hand whole
    clusters to one shard when members arrive cluster-sorted, e.g. from
    ``_live_members`` on the checkpoint-restore path, inflating T toward
    the unsharded value.) Each shard then packs with :func:`_pack_tiles`,
    padded to the largest shard's tiles-per-cluster so the stacked array
    row-shards cleanly over a mesh. Returns
    ``(tile_coords (S*C*T, tile_rows, k), tile_ids (S*C*T, tile_rows), T)``.
    """
    n = len(ids)
    order = np.argsort(assign, kind="stable") if n else np.zeros(0, np.int64)
    shard_of = np.empty(n, np.int64)
    shard_of[order] = np.arange(n) % n_shards  # round-robin within cluster
    T = max(
        max(1, -(-int(np.bincount(assign[shard_of == s],
                                  minlength=n_clusters).max()
                      if (shard_of == s).any() else 0) // tile_rows))
        for s in range(n_shards)
    )
    packed_s, ids_s = [], []
    for s in range(n_shards):
        sel = shard_of == s
        packed, out_ids, _ = _pack_tiles(
            coords[sel], assign[sel], ids[sel], n_clusters, tile_rows,
            min_tiles=T)
        packed_s.append(packed)
        ids_s.append(out_ids)
    kdim = coords.shape[1]
    tile_coords = np.stack(packed_s).reshape(
        n_shards * n_clusters * T, tile_rows, kdim)
    tile_ids = np.stack(ids_s).reshape(
        n_shards * n_clusters * T, tile_rows)
    return tile_coords, tile_ids, T


@dataclasses.dataclass
class ShardedIVFZenIndex:
    """IVF index row-sharded over a device mesh.

    One global quantizer; each shard packs the inverted lists of its own row
    range (global ids), padded to a common tiles-per-cluster so the stacked
    (S*C*T, tile_rows, k) tile array row-shards cleanly over the mesh. A
    query probes the same clusters on every shard (centroids are replicated)
    and the per-shard candidates merge host-side — the same shard_map pattern
    as ``distributed.sharded_knn_search``.

    Mutation is a single-host (control-plane) concern: churn a host
    ``IVFZenIndex``, ``save`` it, and ``ShardedIVFZenIndex.load`` the
    snapshot onto the serving mesh — the snapshot format is shared, so a
    save from S devices reloads onto any other device count.
    """

    centroids: Array    # (C, k) — replicated
    tile_coords: Array  # (S*C*T, tile_rows, k) — row-sharded over the mesh
    tile_ids: Array     # (S*C*T, tile_rows) int32 global ids, -1 = padding
    n_clusters: int
    tiles_per_cluster: int
    tile_rows: int
    n_valid: int
    n_shards: int
    mesh: object
    axis_names: Tuple[str, ...]
    storage: str = "float32"        # resident dtype of tile_coords
    tile_scales: Optional[Array] = None  # (C, 1) — replicated, like centroids

    @property
    def size(self) -> int:
        return self.n_valid

    @property
    def dim(self) -> int:
        return int(self.centroids.shape[1])

    @classmethod
    def build(
        cls,
        coords: Array,
        n_clusters: int,
        *,
        mesh,
        axis: Optional[Union[str, Tuple[str, ...]]] = None,
        tile_rows: int = 128,
        n_iters: int = 15,
        chunk: int = 16384,
        key: Optional[Array] = None,
        storage: str = "float32",
    ) -> "ShardedIVFZenIndex":
        """Fit the global quantizer and pack per-shard inverted lists.

        Args mirror :meth:`IVFZenIndex.build` plus:
          mesh: device mesh to row-shard the packed tiles over.
          axis: mesh axis name(s) carrying the shards (default: all axes).
        """
        key = key if key is not None else jax.random.PRNGKey(0)
        n, _ = coords.shape
        n_clusters = max(1, min(n_clusters, n))
        centroids, _ = kmeans_fit(
            coords, n_clusters, key=key, n_iters=n_iters, chunk=chunk
        )
        assign = np.asarray(kmeans_assign(coords, centroids, chunk=chunk))
        return cls._from_members(
            np.asarray(coords, np.float32), np.arange(n, dtype=np.int64),
            assign.astype(np.int64), centroids, n_clusters, tile_rows,
            mesh=mesh, axis=axis, storage=storage,
        )

    @classmethod
    def _from_members(
        cls,
        coords: np.ndarray,
        ids: np.ndarray,
        assign: np.ndarray,
        centroids: Array,
        n_clusters: int,
        tile_rows: int,
        *,
        mesh,
        axis: Optional[Union[str, Tuple[str, ...]]] = None,
        storage: str = "float32",
        scales: Optional[np.ndarray] = None,
    ) -> "ShardedIVFZenIndex":
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed.retrieval import resolve_axis_names

        # quantise *before* the shard split, with per-cluster scales from
        # the global assignment: the stored bytes are then independent of
        # the shard count, so a snapshot reloads bit-identically onto any
        # mesh (scales are replicated, like the centroids)
        assign64 = np.asarray(assign, np.int64)
        coords, scales = _coerce_member_storage(
            coords, assign64, n_clusters, storage, scales)

        axis_names = resolve_axis_names(mesh, axis)
        n_shards = math.prod(mesh.shape[a] for a in axis_names)
        tile_coords, tile_ids, T = _pack_sharded_tiles(
            coords, assign64, ids, n_clusters, n_shards, tile_rows)
        rows = axis_names if len(axis_names) > 1 else axis_names[0]
        tile_coords = jax.device_put(
            jnp.asarray(tile_coords), NamedSharding(mesh, P(rows, None, None)))
        tile_ids = jax.device_put(
            jnp.asarray(tile_ids), NamedSharding(mesh, P(rows, None)))
        return cls(
            centroids=jnp.asarray(centroids), tile_coords=tile_coords,
            tile_ids=tile_ids, n_clusters=n_clusters, tiles_per_cluster=T,
            tile_rows=tile_rows, n_valid=len(ids), n_shards=n_shards,
            mesh=mesh, axis_names=axis_names, storage=storage,
            tile_scales=None if scales is None else jnp.asarray(scales),
        )

    # -- persistence ---------------------------------------------------------
    def _live_members(
        self, *, raw: bool = False
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Gather the live rows of every shard to host (global ids).

        ``raw`` keeps coords in the storage dtype (snapshot path); the
        default dequantises to f32."""
        tids = np.asarray(self.tile_ids)          # (S*C*T, rows)
        valid = tids >= 0
        ct = self.n_clusters * self.tiles_per_cluster
        block_cluster = (np.arange(tids.shape[0]) % ct) // \
            self.tiles_per_cluster
        assign = np.broadcast_to(block_cluster[:, None], tids.shape)[valid]
        tiles = np.asarray(self.tile_coords)
        if not raw:
            if self.tile_scales is not None:
                per_block = np.asarray(
                    self.tile_scales, np.float32)[:, 0][block_cluster]
                tiles = quant.dequantize(tiles, per_block[:, None, None])
            else:
                tiles = tiles.astype(np.float32)
        coords = tiles[valid]
        return (coords, tids[valid].astype(np.int64),
                assign.astype(np.int64))

    def save(self, directory: str) -> str:
        """Persist the sharded index: gather every shard's live rows to host
        and write the same canonical snapshot as ``IVFZenIndex.save`` —
        device count is a *load-time* choice, not baked into the files."""
        return index_io.save_state(
            directory, *snapshot_payload(self), kind=IVF_SNAPSHOT_KIND)

    @classmethod
    def load(
        cls,
        directory: str,
        *,
        mesh,
        axis: Optional[Union[str, Tuple[str, ...]]] = None,
        tile_rows: Optional[int] = None,
    ) -> "ShardedIVFZenIndex":
        """Load an IVF snapshot and reshard it onto ``mesh``.

        The snapshot carries no device layout, so the target mesh may have a
        different device count than the saver (elastic restore: scale the
        serving fleet up or down across restarts). Members are re-split into
        per-shard inverted lists here; search results are identical to the
        single-host load up to equal-distance tie order.
        """
        arrays, meta = index_io.load_state(
            directory, expect_kind=IVF_SNAPSHOT_KIND)
        return cls._from_members(
            arrays["member_coords"],
            arrays["member_ids"].astype(np.int64),
            arrays["member_assign"].astype(np.int64),
            jnp.asarray(arrays["centroids"]),
            int(meta["n_clusters"]),
            tile_rows or int(meta["tile_rows"]),
            mesh=mesh, axis=axis,
            storage=meta.get("storage", "float32"),
            scales=arrays.get("cluster_scales"),
        )

    def search(
        self,
        queries: Array,
        n_neighbors: int = 10,
        nprobe: int = 8,
        mode: str = "zen",
        *,
        force_kernel: bool = False,
        alive: Optional[Array] = None,
    ) -> Tuple[Array, Array]:
        """Per-shard IVF probe + on-mesh ring merge (global ids).

        ``alive`` is an optional (n_shards,) bool mask (degraded serving):
        a False shard's candidates are dropped inside the merge.
        """
        from repro.distributed import retrieval as retrieval_lib

        assert n_neighbors > 0, n_neighbors
        if self.n_valid == 0:
            return _empty_result(queries.shape[0], n_neighbors)
        n_neighbors = min(n_neighbors, self.n_valid)
        nprobe = max(1, min(nprobe, self.n_clusters))
        probes = _probe_clusters(queries, self.centroids, nprobe, mode)
        return retrieval_lib.sharded_ivf_probe(
            queries, self.tile_coords, self.tile_ids, probes, n_neighbors,
            mode, mesh=self.mesh, axis=self.axis_names,
            tiles_per_cluster=self.tiles_per_cluster,
            tile_scales=self.tile_scales, force_kernel=force_kernel,
            alive=alive,
        )


# -- tiered (host-offloaded) serving ------------------------------------------


class TieredIVFZenIndex:
    """Serve-only IVF index whose inverted lists live in a host-resident pool.

    The all-resident layouts above keep every packed tile in device memory,
    so the corpus is capped by HBM. This tier splits the same layout:

      * **device-resident**: the coarse-quantizer centroids, the per-cluster
        dequant scales, and a configurable *hot set* of high-traffic
        clusters (plus one always-empty dummy cluster that absorbs probe
        slots pointing at cold or dead clusters);
      * **host-resident**: the full ``(C*T, tile_rows, k)`` tile pool as
        plain numpy — optionally a read-only memmap of a
        :data:`TILE_POOL_SNAPSHOT_KIND` snapshot (:meth:`load`), in which
        case cold tiles are paged straight off disk.

    A search runs the normal coarse probe, answers the hot part of every
    probe list from the resident hot set, and walks the cold probe columns
    in fixed-width chunks: the upload for chunk ``j+1`` is *issued* (an
    async transfer — ``kernels.tile_stage.stage_blocks``: Pallas DMA
    through pinned host memory on TPU, plain ``device_put`` elsewhere)
    before chunk ``j`` is scored, so ``ivf_probe`` never waits on a cold
    tile it already knew it needed. Upload buffers are bucketed to
    power-of-two cluster counts, which bounds the distinct probe-kernel
    shapes (and therefore recompiles) to O(log C).

    Results are bit-compatible with ``IVFZenIndex.search`` at equal
    ``nprobe`` up to the ordering of exactly-tied distances: the same
    kernel scores the same probed tiles, only partitioned differently.

    For degraded serving the clusters are statically partitioned over
    ``n_shards`` logical shards (cluster ``c`` lives on shard ``c %
    n_shards``); :meth:`set_dead_shards` masks a dead shard's clusters out
    of both passes, so queries keep answering from the survivors with
    reduced recall instead of raising (``launch.serve.ZenServer`` drives
    this from its ``HeartbeatRegistry``).

    The tier is immutable serving state: no upsert/delete/compact — churn
    the resident index and re-offload (:meth:`from_index`).
    """

    def __init__(
        self,
        centroids,
        host_coords: np.ndarray,
        host_ids: np.ndarray,
        *,
        n_clusters: int,
        tiles_per_cluster: int,
        tile_rows: int,
        n_valid: int,
        storage: str = "float32",
        host_scales: Optional[np.ndarray] = None,
        hot_clusters: Optional[np.ndarray] = None,
        prefetch_cols: int = 2,
        n_shards: int = 1,
        force_stage_kernel: bool = False,
        generation: int = 0,
    ):
        ct = n_clusters * tiles_per_cluster
        assert host_coords.shape[:2] == (ct, tile_rows), host_coords.shape
        assert host_ids.shape == (ct, tile_rows), host_ids.shape
        assert n_shards >= 1, n_shards
        self.centroids = jnp.asarray(centroids)
        self.host_coords = host_coords
        self.host_ids = host_ids
        self.host_scales = (None if host_scales is None
                            else np.asarray(host_scales, np.float32))
        self.n_clusters = n_clusters
        self.tiles_per_cluster = tiles_per_cluster
        self.tile_rows = tile_rows
        self.n_valid = n_valid
        self.n_deleted = 0
        self.storage = storage
        self.prefetch_cols = max(1, prefetch_cols)
        self.n_shards = n_shards
        self.force_stage_kernel = force_stage_kernel
        self.generation = generation
        self.dead_shards: list = []
        self._dead_cluster = np.zeros(n_clusters, bool)
        self._traffic = np.zeros(n_clusters, np.int64)
        self._hot_hits = 0
        self._cold_uploads = 0
        self._bytes_uploaded = 0
        self._max_chunk_bytes = 0
        if hot_clusters is None:
            hot_clusters = np.empty(0, np.int64)
        self._set_hot(np.asarray(hot_clusters, np.int64))

    # -- construction --------------------------------------------------------
    @classmethod
    def from_index(
        cls,
        index: IVFZenIndex,
        *,
        hot_clusters: Optional[int] = None,
        hot_fraction: float = 0.1,
        prefetch_cols: int = 2,
        n_shards: int = 1,
        force_stage_kernel: bool = False,
    ) -> "TieredIVFZenIndex":
        """Offload a resident index: tiles drop to host, a hot set stays.

        The initial hot set is the ``hot_clusters`` (default
        ``hot_fraction`` of C) largest clusters by live member count — the
        best traffic proxy before any query lands; :meth:`refresh_hot`
        re-picks by observed probe traffic.
        """
        if index.storage == "pq":
            raise NotImplementedError(
                "tiered offload does not support storage='pq' (its probe "
                "scores coordinates, not codes); compact to one of "
                + "/".join(quant.SCALAR_STORAGE_DTYPES) + " first")
        C = index.n_clusters
        sizes = index.cluster_sizes()
        H = (max(0, min(int(hot_clusters), C)) if hot_clusters is not None
             else max(1, int(C * hot_fraction)))
        hot = np.sort(np.argsort(sizes, kind="stable")[::-1][:H])
        return cls(
            index.centroids,
            np.asarray(index.tile_coords),
            np.asarray(index.tile_ids, np.int32),
            n_clusters=C,
            tiles_per_cluster=index.tiles_per_cluster,
            tile_rows=index.tile_rows,
            n_valid=index.n_valid,
            storage=index.storage,
            host_scales=(None if index.tile_scales is None
                         else np.asarray(index.tile_scales, np.float32)),
            hot_clusters=hot,
            prefetch_cols=prefetch_cols,
            n_shards=n_shards,
            force_stage_kernel=force_stage_kernel,
            generation=index.generation,
        )

    @property
    def size(self) -> int:
        return self.n_valid

    @property
    def dim(self) -> int:
        return int(self.centroids.shape[1])

    @property
    def tile_scales(self):
        """Host view of the per-cluster scales (snapshot-payload contract)."""
        return self.host_scales

    # -- hot set -------------------------------------------------------------
    def _set_hot(self, hot: np.ndarray) -> None:
        """(Re)upload the hot cluster set + the trailing dummy cluster."""
        C, T, rows = self.n_clusters, self.tiles_per_cluster, self.tile_rows
        kdim = self.host_coords.shape[2]
        self.hot_clusters = np.sort(hot.astype(np.int64))
        H = self.hot_clusters.size
        blocks = (self.hot_clusters[:, None] * T + np.arange(T)).reshape(-1)
        coords = np.zeros((
            (H + 1) * T, rows, kdim), self.host_coords.dtype)
        ids = np.full(((H + 1) * T, rows), -1, np.int32)
        if H:
            coords[:H * T] = self.host_coords[blocks]
            ids[:H * T] = self.host_ids[blocks]
        self._hot_coords = tile_stage.stage_blocks(
            coords, force_kernel=self.force_stage_kernel)
        self._hot_ids = tile_stage.stage_blocks(
            ids, force_kernel=self.force_stage_kernel)
        if self.host_scales is None:
            self._hot_scales = None
        else:
            hs = np.ones((H + 1, 1), np.float32)
            if H:
                hs[:H] = self.host_scales[self.hot_clusters]
            self._hot_scales = jnp.asarray(hs)
        base = np.full(C, H, np.int32)  # cold clusters -> the dummy slot
        base[self.hot_clusters] = np.arange(H, dtype=np.int32)
        self._base_slot = base
        self._refresh_slot()

    def _refresh_slot(self) -> None:
        dummy = np.int32(self.hot_clusters.size)
        self._hot_slot = np.where(self._dead_cluster, dummy, self._base_slot)

    def refresh_hot(self, hot_clusters: Optional[int] = None) -> None:
        """Re-pick the hot set from observed probe traffic and re-upload."""
        H = (self.hot_clusters.size if hot_clusters is None
             else max(0, min(int(hot_clusters), self.n_clusters)))
        order = np.argsort(self._traffic, kind="stable")[::-1]
        self._set_hot(np.sort(order[:H]))

    # -- degraded serving ----------------------------------------------------
    def shard_of_cluster(self) -> np.ndarray:
        """(C,) logical shard owning each cluster."""
        return np.arange(self.n_clusters) % self.n_shards

    def set_dead_shards(self, shards) -> None:
        """Mask the given logical shards' clusters out of every probe."""
        dead = sorted({int(s) for s in shards})
        for s in dead:
            if not 0 <= s < self.n_shards:
                raise ValueError(
                    f"shard {s} out of range for n_shards={self.n_shards}")
        self.dead_shards = dead
        self._dead_cluster = np.isin(self.shard_of_cluster(), dead)
        self._refresh_slot()

    # -- memory accounting ---------------------------------------------------
    def device_bytes(self) -> int:
        """Device-resident footprint: centroids + hot set + the (double-
        buffered) peak cold upload, the figure the benchmark holds flat."""
        resident = (self.centroids.nbytes + self._hot_coords.nbytes
                    + self._hot_ids.nbytes)
        if self._hot_scales is not None:
            resident += self._hot_scales.nbytes
        return resident + 2 * self._max_chunk_bytes

    def provisioned_device_bytes(self, n_queries: int) -> int:
        """Worst-case device high-water mark for ``n_queries``-row batches:
        the resident arrays plus both staging buffers at the largest slot
        bucket ``_stage_chunk`` can allocate for that batch shape. Unlike
        ``device_bytes`` (the observed mark) this does not depend on which
        clusters the traffic happened to touch, so it is the figure to
        provision — and to compare across corpus sizes."""
        worst_uniq = min(int(n_queries) * self.prefetch_cols, self.n_clusters)
        n_slots = min(1 << worst_uniq.bit_length(), self.n_clusters + 1)
        n_slots = max(n_slots, worst_uniq + 1)
        T, rows = self.tiles_per_cluster, self.tile_rows
        kdim = self.host_coords.shape[2]
        per_slot = T * rows * (kdim * self.host_coords.dtype.itemsize + 4)
        chunk = n_slots * per_slot
        if self.host_scales is not None:
            chunk += n_slots * 4
        resident = (self.centroids.nbytes + self._hot_coords.nbytes
                    + self._hot_ids.nbytes)
        if self._hot_scales is not None:
            resident += self._hot_scales.nbytes
        return resident + 2 * chunk

    def host_bytes(self) -> int:
        out = self.host_coords.nbytes + self.host_ids.nbytes
        if self.host_scales is not None:
            out += self.host_scales.nbytes
        return out

    def stats(self) -> dict:
        return {
            "hot_clusters": int(self.hot_clusters.size),
            "hot_hits": int(self._hot_hits),
            "cold_uploads": int(self._cold_uploads),
            "bytes_uploaded": int(self._bytes_uploaded),
            "device_bytes": self.device_bytes(),
            "host_bytes": self.host_bytes(),
            "dead_shards": list(self.dead_shards),
            "masked_clusters": int(self._dead_cluster.sum()),
        }

    # -- search --------------------------------------------------------------
    def _stage_chunk(self, sub, subcold):
        """Build + launch the upload for one cold probe-column chunk.

        Returns ``(coords, ids, scales, remapped_probes)`` device handles
        (transfers in flight), or None when the chunk has no cold cluster.
        ``sub``/``subcold``: (Q, w) probe ids and their cold-and-alive mask.
        """
        uniq = np.unique(sub[subcold])
        if uniq.size == 0:
            return None
        T, rows = self.tiles_per_cluster, self.tile_rows
        kdim = self.host_coords.shape[2]
        # power-of-two slot bucket (incl. the dummy) bounds recompiles
        n_slots = min(1 << int(uniq.size).bit_length(), self.n_clusters + 1)
        n_slots = max(n_slots, uniq.size + 1)
        slot = np.full(self.n_clusters, n_slots - 1, np.int32)
        slot[uniq] = np.arange(uniq.size, dtype=np.int32)
        remapped = np.where(subcold, slot[sub], n_slots - 1).astype(np.int32)
        blocks = (uniq[:, None] * T + np.arange(T)).reshape(-1)
        coords = np.zeros((n_slots * T, rows, kdim), self.host_coords.dtype)
        ids = np.full((n_slots * T, rows), -1, np.int32)
        coords[:uniq.size * T] = self.host_coords[blocks]
        ids[:uniq.size * T] = self.host_ids[blocks]
        scales = None
        if self.host_scales is not None:
            hs = np.ones((n_slots, 1), np.float32)
            hs[:uniq.size] = self.host_scales[uniq]
            scales = jnp.asarray(hs)
        up_bytes = coords.nbytes + ids.nbytes
        self._cold_uploads += 1
        self._bytes_uploaded += up_bytes
        self._max_chunk_bytes = max(self._max_chunk_bytes, up_bytes)
        return (
            tile_stage.stage_blocks(
                coords, force_kernel=self.force_stage_kernel),
            tile_stage.stage_blocks(
                ids, force_kernel=self.force_stage_kernel),
            scales,
            jnp.asarray(remapped),
        )

    def search(
        self,
        queries: Array,
        n_neighbors: int = 10,
        nprobe: int = 8,
        mode: str = "zen",
        *,
        force_kernel: bool = False,
    ) -> Tuple[Array, Array]:
        """Hot-set probe + double-buffered cold-chunk probes, merged.

        Same contract as ``IVFZenIndex.search``; dead shards' clusters are
        silently skipped (degraded mode), which lowers recall but never
        raises.
        """
        assert n_neighbors > 0, n_neighbors
        if self.n_valid == 0:
            return _empty_result(queries.shape[0], n_neighbors)
        n_neighbors = min(n_neighbors, self.n_valid)
        nprobe = max(1, min(nprobe, self.n_clusters))
        T = self.tiles_per_cluster
        probes = np.asarray(
            _probe_clusters(queries, self.centroids, nprobe, mode))
        np.add.at(self._traffic, probes.reshape(-1), 1)

        # hot pass: the full probe list with cold/dead entries remapped to
        # the dummy slot — answers everything the hot set can
        hot_pr = self._hot_slot[probes]
        H = self.hot_clusters.size
        self._hot_hits += int((hot_pr < H).sum())
        best_d, best_i = kernel_ops.ivf_probe(
            queries, self._hot_coords, self._hot_ids, jnp.asarray(hot_pr),
            n_neighbors, mode, tiles_per_cluster=T,
            tile_scales=self._hot_scales, force_kernel=force_kernel,
        )

        # cold passes: probe columns in fixed-width chunks; the upload for
        # chunk j+1 is in flight while chunk j is being scored
        cold = (~self._dead_cluster & (self._base_slot == H))[probes]
        w = self.prefetch_cols
        spans = [(lo, min(lo + w, nprobe)) for lo in range(0, nprobe, w)]
        staged = self._stage_chunk(
            probes[:, spans[0][0]:spans[0][1]],
            cold[:, spans[0][0]:spans[0][1]]) if spans else None
        for j, (lo, hi) in enumerate(spans):
            cur, staged = staged, None
            if j + 1 < len(spans):
                nlo, nhi = spans[j + 1]
                staged = self._stage_chunk(
                    probes[:, nlo:nhi], cold[:, nlo:nhi])
            if cur is None:
                continue
            up_coords, up_ids, up_scales, remapped = cur
            d, i = kernel_ops.ivf_probe(
                queries, up_coords, up_ids, remapped, n_neighbors, mode,
                tiles_per_cluster=T, tile_scales=up_scales,
                force_kernel=force_kernel,
            )
            best_d, best_i = scoring.merge_topk(
                best_d, best_i, d, i, n_neighbors)
        return best_d, best_i

    # -- persistence ---------------------------------------------------------
    def _live_members(
        self, *, raw: bool = False
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Host copies of the live rows (same contract as the resident
        variants) — lets ``snapshot_payload`` serve a tiered index too."""
        valid = self.host_ids >= 0
        block_cluster = (np.arange(self.host_ids.shape[0])
                         // self.tiles_per_cluster)
        assign = np.broadcast_to(
            block_cluster[:, None], self.host_ids.shape)[valid]
        coords = np.asarray(self.host_coords)
        if not raw and self.host_scales is not None:
            per_block = np.repeat(
                self.host_scales[:, 0], self.tiles_per_cluster)
            coords = quant.dequantize(coords, per_block[:, None, None])
        elif not raw:
            coords = np.asarray(coords, np.float32)
        return (coords[valid], self.host_ids[valid].astype(np.int64),
                assign.astype(np.int64))

    def save(self, directory: str) -> str:
        """Persist the packed tile pool itself (memmap-servable layout)."""
        arrays = {
            "centroids": np.asarray(self.centroids, np.float32),
            "tile_coords": np.asarray(self.host_coords),
            "tile_ids": np.asarray(self.host_ids, np.int32),
        }
        if self.host_scales is not None:
            arrays["cluster_scales"] = self.host_scales
        meta = {
            "n_clusters": self.n_clusters,
            "tiles_per_cluster": self.tiles_per_cluster,
            "tile_rows": self.tile_rows,
            "n_valid": self.n_valid,
            "storage": self.storage,
            "n_shards": self.n_shards,
            "generation": int(self.generation),
        }
        return index_io.save_state(
            directory, arrays, meta, kind=TILE_POOL_SNAPSHOT_KIND)

    @classmethod
    def load(
        cls,
        directory: str,
        *,
        mmap: bool = True,
        hot_clusters: Optional[int] = None,
        hot_fraction: float = 0.1,
        prefetch_cols: int = 2,
        n_shards: Optional[int] = None,
        force_stage_kernel: bool = False,
    ) -> "TieredIVFZenIndex":
        """Open a tile-pool snapshot; with ``mmap`` the cold tiles never
        materialise in RAM — only probed blocks are read."""
        arrays, meta = index_io.load_state(
            directory, expect_kind=TILE_POOL_SNAPSHOT_KIND, mmap=mmap)
        host_ids = arrays["tile_ids"]
        C, T = int(meta["n_clusters"]), int(meta["tiles_per_cluster"])
        live = (np.asarray(host_ids) >= 0).reshape(C, -1).sum(axis=1)
        H = (max(0, min(int(hot_clusters), C)) if hot_clusters is not None
             else max(1, int(C * hot_fraction)))
        hot = np.sort(np.argsort(live, kind="stable")[::-1][:H])
        return cls(
            jnp.asarray(arrays["centroids"]),
            arrays["tile_coords"],
            host_ids,
            n_clusters=C,
            tiles_per_cluster=T,
            tile_rows=int(meta["tile_rows"]),
            n_valid=int(meta["n_valid"]),
            storage=meta.get("storage", "float32"),
            host_scales=arrays.get("cluster_scales"),
            hot_clusters=hot,
            prefetch_cols=prefetch_cols,
            n_shards=int(meta.get("n_shards", 1)) if n_shards is None
            else n_shards,
            force_stage_kernel=force_stage_kernel,
            generation=int(meta.get("generation", 0)),
        )
