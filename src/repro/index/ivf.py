"""IVFZenIndex — clustered (inverted-file) retrieval over apex coordinates.

Filter-and-refine at production scale (paper §Perf; the supermetric-search
predecessor arXiv:1707.08370): instead of streaming every one of the N index
rows per query (``core.zen.knn_search``), partition the reduced (N, k)
coordinates with a k-means coarse quantizer and probe only the ``nprobe``
clusters whose centroids are closest to the query. Scan cost per query drops
from O(N) to O(nprobe * max_cluster_size); ``nprobe = n_clusters`` recovers
the flat result exactly.

Padded tile layout
------------------
Cluster sizes are data-dependent, so the inverted lists are packed into a
*static* shape: members are sorted by cluster and written into ``T`` fixed
``tile_rows``-row tiles per cluster,

  tile_coords : (C*T, tile_rows, k)   cluster c owns blocks c*T .. c*T+T-1
  tile_ids    : (C*T, tile_rows)      global row ids, -1 marks padding

with ``T`` sized by the largest cluster. Every probe therefore touches the
same block shapes under jit, the Pallas kernel can DMA tiles straight from a
scalar-prefetched probe list, and padding rows are masked (id == -1 -> +inf)
before the running top-k merge — never returned.

``search`` dispatches through ``kernels.ops.ivf_probe``: the fused Pallas
kernel on TPU, a fori_loop gather fallback elsewhere — both bounded-memory
(one tile per query per step). ``exact_rerank`` refines a candidate pool with
true distances in the original space (the PR-1 serving pattern).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import metrics as metrics_lib
from repro.core import zen as zen_lib
from repro.kernels import ops as kernel_ops

from .kmeans import kmeans_assign, kmeans_fit

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IVFZenIndex:
    """Clustered Zen index: k-means centroids + padded inverted-list tiles."""

    centroids: Array    # (C, k) f32 coarse-quantizer centroids
    tile_coords: Array  # (C*T, tile_rows, k) packed member coordinates
    tile_ids: Array     # (C*T, tile_rows) int32 global row ids, -1 = padding
    n_clusters: int
    tiles_per_cluster: int
    tile_rows: int
    n_valid: int        # number of real (un-padded) index rows

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        children = (self.centroids, self.tile_coords, self.tile_ids)
        aux = (self.n_clusters, self.tiles_per_cluster, self.tile_rows,
               self.n_valid)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def size(self) -> int:
        return self.n_valid

    @property
    def dim(self) -> int:
        return int(self.centroids.shape[1])

    # -- build ---------------------------------------------------------------
    @classmethod
    def build(
        cls,
        coords: Array,
        n_clusters: int,
        *,
        tile_rows: int = 128,
        n_iters: int = 15,
        chunk: int = 16384,
        key: Optional[Array] = None,
    ) -> "IVFZenIndex":
        """Cluster (N, k) apex coordinates and pack the inverted lists.

        The quantizer fit and assignment run jit-compiled and chunked
        (``index.kmeans``); the pack itself is a one-off host-side sort.
        ``tile_rows`` should stay a multiple of 128 so tiles are lane-aligned
        for the TPU probe kernel.
        """
        key = key if key is not None else jax.random.PRNGKey(0)
        n, kdim = coords.shape
        n_clusters = max(1, min(n_clusters, n))
        centroids, _ = kmeans_fit(
            coords, n_clusters, key=key, n_iters=n_iters, chunk=chunk
        )
        assign = np.asarray(kmeans_assign(coords, centroids, chunk=chunk))

        counts = np.bincount(assign, minlength=n_clusters)
        per_cluster = max(tile_rows, int(
            math.ceil(counts.max() / tile_rows)) * tile_rows)
        T = per_cluster // tile_rows
        ids = np.full((n_clusters, per_cluster), -1, np.int64)
        order = np.argsort(assign, kind="stable")
        starts = np.cumsum(counts) - counts
        pos = np.arange(n) - np.repeat(starts, counts)
        ids[assign[order], pos] = order
        packed = np.zeros((n_clusters, per_cluster, kdim), np.float32)
        valid = ids >= 0
        packed[valid] = np.asarray(coords, np.float32)[ids[valid]]

        return cls(
            centroids=centroids,
            tile_coords=jnp.asarray(
                packed.reshape(n_clusters * T, tile_rows, kdim)),
            tile_ids=jnp.asarray(
                ids.reshape(n_clusters * T, tile_rows).astype(np.int32)),
            n_clusters=n_clusters,
            tiles_per_cluster=T,
            tile_rows=tile_rows,
            n_valid=n,
        )

    # -- search --------------------------------------------------------------
    def search(
        self,
        queries: Array,
        n_neighbors: int = 10,
        nprobe: int = 8,
        mode: str = "zen",
        *,
        force_kernel: bool = False,
    ) -> Tuple[Array, Array]:
        """Probe the ``nprobe`` nearest clusters per query, return best-k.

        Returns (distances, indices), each (Q, n_neighbors), ascending; ids
        refer to rows of the original coordinate matrix (valid ids only —
        slots the probed clusters cannot fill come back as (+inf, -1)).
        ``nprobe = n_clusters`` scans every list and matches the flat
        ``knn_search`` result exactly.
        """
        n_neighbors = min(n_neighbors, self.n_valid)
        nprobe = max(1, min(nprobe, self.n_clusters))
        return _ivf_search(
            self, queries, n_neighbors=n_neighbors, nprobe=nprobe, mode=mode,
            force_kernel=force_kernel,
        )

    def probe_clusters(
        self, queries: Array, nprobe: int, mode: str = "zen"
    ) -> Array:
        """(Q, nprobe) ids of the clusters nearest each query's coordinates."""
        nprobe = max(1, min(nprobe, self.n_clusters))
        return _probe_clusters(queries, self.centroids, nprobe, mode)


def _probe_clusters(
    queries: Array, centroids: Array, nprobe: int, mode: str
) -> Array:
    """Coarse ranking: the ``nprobe`` estimator-nearest centroids per query.

    One small (Q, C) matrix — the sublinear part of the search is never
    materialising anything N-sized after this. The single shared
    implementation keeps single-host, sharded and diagnostic probes
    identical (same tie-breaking, same estimator mode).
    """
    cd = zen_lib.estimate_pdist(queries, centroids, mode)
    _, probes = jax.lax.top_k(-cd, nprobe)
    return probes.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n_neighbors", "nprobe", "mode", "force_kernel"),
)
def _ivf_search(
    index: IVFZenIndex,
    queries: Array,
    *,
    n_neighbors: int,
    nprobe: int,
    mode: str,
    force_kernel: bool,
) -> Tuple[Array, Array]:
    probes = _probe_clusters(queries, index.centroids, nprobe, mode)
    return kernel_ops.ivf_probe(
        queries, index.tile_coords, index.tile_ids, probes, n_neighbors,
        mode, tiles_per_cluster=index.tiles_per_cluster,
        force_kernel=force_kernel,
    )


def exact_rerank(
    queries: Array,
    corpus: Array,
    cand_ids: Array,
    n_neighbors: int,
    *,
    metric: str = "euclidean",
) -> Tuple[Array, Array]:
    """Refine a (Q, C) candidate pool with true distances (serving pattern).

    Gathers the candidates' original vectors, scores them exactly under
    ``metric``'s normalisation, and returns the best ``n_neighbors``.
    Padding candidates (id == -1) are masked out, never returned (unless the
    pool holds fewer than ``n_neighbors`` valid candidates).
    """
    m = metrics_lib.get_metric(metric)
    safe_ids = jnp.maximum(cand_ids, 0)
    cands = corpus[safe_ids]                         # (Q, C, m)
    qn = m.normalize(queries) if m.normalize is not None else queries
    cn = m.normalize(cands) if m.normalize is not None else cands
    d = jnp.linalg.norm(
        qn[:, None, :].astype(jnp.float32) - cn.astype(jnp.float32), axis=-1
    )
    d = jnp.where(cand_ids >= 0, d, jnp.inf)
    n_neighbors = min(n_neighbors, cand_ids.shape[1])
    dd, pos = jax.lax.top_k(-d, n_neighbors)
    return -dd, jnp.take_along_axis(cand_ids, pos, axis=1)


@dataclasses.dataclass
class ShardedIVFZenIndex:
    """IVF index row-sharded over a device mesh.

    One global quantizer; each shard packs the inverted lists of its own row
    range (global ids), padded to a common tiles-per-cluster so the stacked
    (S*C*T, tile_rows, k) tile array row-shards cleanly over the mesh. A
    query probes the same clusters on every shard (centroids are replicated)
    and the per-shard candidates merge host-side — the same shard_map pattern
    as ``distributed.sharded_knn_search``.
    """

    centroids: Array    # (C, k) — replicated
    tile_coords: Array  # (S*C*T, tile_rows, k) — row-sharded over the mesh
    tile_ids: Array     # (S*C*T, tile_rows) int32 global ids, -1 = padding
    n_clusters: int
    tiles_per_cluster: int
    tile_rows: int
    n_valid: int
    n_shards: int
    mesh: object
    axis_names: Tuple[str, ...]

    @property
    def size(self) -> int:
        return self.n_valid

    @classmethod
    def build(
        cls,
        coords: Array,
        n_clusters: int,
        *,
        mesh,
        axis: Optional[Union[str, Tuple[str, ...]]] = None,
        tile_rows: int = 128,
        n_iters: int = 15,
        chunk: int = 16384,
        key: Optional[Array] = None,
    ) -> "ShardedIVFZenIndex":
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed.retrieval import resolve_axis_names

        axis_names = resolve_axis_names(mesh, axis)
        n_shards = math.prod(mesh.shape[a] for a in axis_names)

        key = key if key is not None else jax.random.PRNGKey(0)
        n, kdim = coords.shape
        n_clusters = max(1, min(n_clusters, n))
        centroids, _ = kmeans_fit(
            coords, n_clusters, key=key, n_iters=n_iters, chunk=chunk
        )
        assign = np.asarray(kmeans_assign(coords, centroids, chunk=chunk))
        coords_np = np.asarray(coords, np.float32)

        # contiguous row ranges per shard, packed with *global* ids
        rows_per = -(-n // n_shards)  # ceil
        bounds = [
            (s * rows_per, min((s + 1) * rows_per, n))
            for s in range(n_shards)
        ]
        per_shard_max = max(
            int(np.bincount(assign[lo:hi], minlength=n_clusters).max())
            if hi > lo else 0
            for lo, hi in bounds
        )
        per_cluster = max(tile_rows, int(
            math.ceil(per_shard_max / tile_rows)) * tile_rows)
        T = per_cluster // tile_rows

        ids = np.full((n_shards, n_clusters, per_cluster), -1, np.int64)
        packed = np.zeros(
            (n_shards, n_clusters, per_cluster, kdim), np.float32)
        for s, (lo, hi) in enumerate(bounds):
            a = assign[lo:hi]
            counts = np.bincount(a, minlength=n_clusters)
            order = np.argsort(a, kind="stable")
            starts = np.cumsum(counts) - counts
            pos = np.arange(hi - lo) - np.repeat(starts, counts)
            ids[s, a[order], pos] = order + lo
            valid = ids[s] >= 0
            packed[s][valid] = coords_np[ids[s][valid]]

        tile_coords = jnp.asarray(
            packed.reshape(n_shards * n_clusters * T, tile_rows, kdim))
        tile_ids = jnp.asarray(
            ids.reshape(n_shards * n_clusters * T, tile_rows)
            .astype(np.int32))
        rows = axis_names if len(axis_names) > 1 else axis_names[0]
        tile_coords = jax.device_put(
            tile_coords, NamedSharding(mesh, P(rows, None, None)))
        tile_ids = jax.device_put(
            tile_ids, NamedSharding(mesh, P(rows, None)))
        return cls(
            centroids=centroids, tile_coords=tile_coords, tile_ids=tile_ids,
            n_clusters=n_clusters, tiles_per_cluster=T, tile_rows=tile_rows,
            n_valid=n, n_shards=n_shards, mesh=mesh, axis_names=axis_names,
        )

    def search(
        self,
        queries: Array,
        n_neighbors: int = 10,
        nprobe: int = 8,
        mode: str = "zen",
        *,
        force_kernel: bool = False,
    ) -> Tuple[Array, Array]:
        """Per-shard IVF probe + host-side candidate merge (global ids)."""
        from repro.distributed import retrieval as retrieval_lib

        n_neighbors = min(n_neighbors, self.n_valid)
        nprobe = max(1, min(nprobe, self.n_clusters))
        probes = _probe_clusters(queries, self.centroids, nprobe, mode)
        return retrieval_lib.sharded_ivf_probe(
            queries, self.tile_coords, self.tile_ids, probes, n_neighbors,
            mode, mesh=self.mesh, axis=self.axis_names,
            tiles_per_cluster=self.tiles_per_cluster,
            force_kernel=force_kernel,
        )
