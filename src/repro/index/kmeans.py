"""Batched Lloyd's k-means over apex coordinates — the IVF coarse quantizer.

The whole fit is jit-compiled and bounded-memory: the assignment pass walks
the (N, k) coordinate matrix in fixed-size row chunks (one (chunk, C) distance
block live at a time, same clamped-tail dynamic-slice pattern as
``kernels.zen_topk.zen_topk_scan``), and the update pass is two segment-sums.

Seeding is k-means++-style D² sampling (first centroid uniform, then each next
centroid drawn with probability proportional to the squared distance to the
nearest already-chosen centroid), the same spread-the-references intuition as
``core.projection.select_references``' redraw loop but with a deterministic
key. Empty clusters are reseeded each iteration to the points currently
farthest from their assigned centroid, so the quantizer cannot silently
collapse onto fewer than ``n_clusters`` cells on degenerate data.

Clustering runs in the *reduced* space under plain Euclidean distance: apex
coordinates live in R^k and the Zen/Lwb/Upb estimators of paper §4.1 are all
monotone in the base-coordinate L2, so Euclidean cells are the right coarse
partition for every estimator mode.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _sq_dist(blk: Array, centroids: Array) -> Array:
    """Squared Euclidean distances (rows, C) between blk and centroids, f32."""
    bn = jnp.sum(blk * blk, axis=1, keepdims=True)
    cn = jnp.sum(centroids * centroids, axis=1)
    dot = jnp.matmul(blk, centroids.T, preferred_element_type=jnp.float32)
    return jnp.maximum(bn + cn[None, :] - 2.0 * dot, 0.0)


def _assign_pass(
    coords: Array, centroids: Array, chunk: int
) -> Tuple[Array, Array]:
    """(assignments (N,), squared distance to own centroid (N,)) — chunked.

    One (chunk, C) block lives at a time; the tail chunk is clamped back like
    the streaming top-k scan, which merely recomputes (identically) a few
    already-visited rows.
    """
    n = coords.shape[0]
    chunk = min(chunk, n)
    n_chunks = -(-n // chunk)  # ceil

    def body(i, carry):
        assign, d2own = carry
        start = jnp.minimum(i * chunk, n - chunk)  # clamp the tail chunk
        blk = jax.lax.dynamic_slice_in_dim(coords, start, chunk, axis=0)
        d2 = _sq_dist(blk, centroids)  # (chunk, C)
        a = jnp.argmin(d2, axis=1).astype(jnp.int32)
        m = jnp.min(d2, axis=1)
        assign = jax.lax.dynamic_update_slice_in_dim(assign, a, start, 0)
        d2own = jax.lax.dynamic_update_slice_in_dim(d2own, m, start, 0)
        return assign, d2own

    init = (
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.float32),
    )
    return jax.lax.fori_loop(0, n_chunks, body, init)


def _seed_plus_plus(coords: Array, n_clusters: int, key: Array) -> Array:
    """k-means++ D² seeding: one (N,)-sized single-centroid distance pass per
    draw — O(N) live state, never an (N, C) temp."""
    n = coords.shape[0]
    first = jax.random.randint(jax.random.fold_in(key, 0), (), 0, n)
    cents = jnp.zeros((n_clusters, coords.shape[1]), jnp.float32)
    cents = cents.at[0].set(coords[first].astype(jnp.float32))

    def min_d2_to(c):
        # (N,) squared distance to a single centroid — no (N, C) temp
        diff = coords.astype(jnp.float32) - c[None, :]
        return jnp.sum(diff * diff, axis=1)

    def body(i, carry):
        cents, min_d2 = carry
        # degenerate data (all residual mass zero) degrades to uniform draws
        logits = jnp.log(jnp.maximum(min_d2, 1e-30))
        idx = jax.random.categorical(jax.random.fold_in(key, i), logits)
        c = coords[idx].astype(jnp.float32)
        cents = cents.at[i].set(c)
        return cents, jnp.minimum(min_d2, min_d2_to(c))

    cents, _ = jax.lax.fori_loop(
        1, n_clusters, body, (cents, min_d2_to(cents[0]))
    )
    return cents


@functools.partial(
    jax.jit, static_argnames=("n_clusters", "n_iters", "chunk")
)
def kmeans_fit(
    coords: Array,
    n_clusters: int,
    *,
    key: Array,
    n_iters: int = 15,
    chunk: int = 16384,
) -> Tuple[Array, Array]:
    """Fit ``n_clusters`` centroids to (N, k) coordinates with Lloyd's method.

    Returns ``(centroids (C, k) f32, inertia ())`` where inertia is the mean
    squared distance of every point to its nearest centroid at the final
    assignment pass — a fixed point of the iteration leaves it unchanged.
    Requires ``n_clusters <= N``.
    """
    n, kdim = coords.shape
    assert 0 < n_clusters <= n, (n_clusters, n)
    coords32 = coords.astype(jnp.float32)
    cents = _seed_plus_plus(coords32, n_clusters, key)

    def step(cents, _):
        assign, d2own = _assign_pass(coords32, cents, chunk)
        counts = jax.ops.segment_sum(
            jnp.ones((n,), jnp.float32), assign, n_clusters
        )
        sums = jax.ops.segment_sum(coords32, assign, n_clusters)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # empty-cluster reseeding: hand the i-th empty cluster the i-th
        # farthest point from its current centroid (all static shapes)
        empty = counts == 0.0
        far_d2, far_ids = jax.lax.top_k(d2own, min(n_clusters, n))
        rank = jnp.clip(jnp.cumsum(empty) - 1, 0, far_ids.shape[0] - 1)
        reseed = coords32[far_ids[rank]]
        new = jnp.where(empty[:, None], reseed, new)
        return new, jnp.sum(d2own) / n

    cents, inertias = jax.lax.scan(step, cents, None, length=n_iters)
    return cents, inertias[-1]


@functools.partial(jax.jit, static_argnames=("chunk",))
def kmeans_assign(
    coords: Array, centroids: Array, *, chunk: int = 16384
) -> Array:
    """Nearest-centroid assignment (N,) int32 — the IVF out-of-sample step."""
    assign, _ = _assign_pass(
        coords.astype(jnp.float32), centroids.astype(jnp.float32), chunk
    )
    return assign
