"""Clustered (IVF) retrieval index over nSimplex-Zen apex coordinates.

``kmeans``   batched Lloyd's k-means in JAX — the coarse quantizer.
``ivf``      IVFZenIndex: padded inverted-list layout + clustered search,
             probing only a few clusters per query (sublinear retrieval),
             plus the mutable-corpus lifecycle (upsert / delete / compact)
             and versioned save / load snapshots.
"""
from .ivf import (
    IVF_SNAPSHOT_KIND,
    IVFZenIndex,
    ShardedIVFZenIndex,
    exact_rerank,
)
from .kmeans import kmeans_assign, kmeans_fit

__all__ = [
    "IVF_SNAPSHOT_KIND",
    "IVFZenIndex",
    "ShardedIVFZenIndex",
    "exact_rerank",
    "kmeans_assign",
    "kmeans_fit",
]
